"""Host-side serving substrate tests: cell-queue admission (paper §3.2 as
admission control), slot-pool lifecycle, traces, and the protocol-name
validation satellite (ValueError instead of silent 1-copy fallthrough)."""

import numpy as np
import pytest

from repro.core import p2p, protocol
from repro.serve import (CellQueueScheduler, LeaseLeakWarning, ServeRequest,
                         SlotError, SlotKVCache, make_trace, shard_trace)


def _req(rid, prompt_len, max_new=8, arrival=0.0):
    return ServeRequest(rid=rid,
                        batch={"tokens": np.zeros((1, prompt_len), np.int32)},
                        max_new_tokens=max_new, arrival=arrival)


# ---------------------------------------------------------------------------
# cell-queue scheduler
# ---------------------------------------------------------------------------

def test_eager_admission_within_cell_budget():
    s = CellQueueScheduler(num_cells=4)
    # 16-token prompt = 64 bytes -> single-cell eager_fast
    assert s.submit(_req(0, 16), now=0.0) == "cells"
    assert s.queue_depths()["cells"] == 1 and s.cells_free == 3
    out = s.admit(now=1.0, free_slots=2)
    assert [q.rid for q in out] == [0]
    assert s.cells_free == 4
    assert out[0].protocol == "eager_fast" and out[0].cells == 1
    assert out[0].admit_time == 1.0 and out[0].submit_time == 0.0


def test_multi_cell_eager_occupancy_and_overflow_promotion():
    # cell_size=1024B -> 256 tokens/cell; 600-token prompt = 2400B:
    # eager class (<= 4096B) but 3 cells
    s = CellQueueScheduler(num_cells=4, cell_size=1024)
    assert s.submit(_req(0, 600), 0.0) == "cells"
    assert s.cells_free == 1
    # next eager request needs 2 cells -> overflows (bounded pool)
    assert s.submit(_req(1, 300), 0.0) == "overflow"
    assert s.n_deferred == 1
    # admitting rid 0 releases its cells and promotes rid 1 FIFO
    out = s.admit(1.0, free_slots=1)
    assert [q.rid for q in out] == [0]
    assert s.queue_depths() == {"cells": 1, "overflow": 0, "rendezvous": 0,
                                "cells_free": 2}
    out = s.admit(2.0, free_slots=4)
    assert [q.rid for q in out] == [1]


def test_eager_request_larger_than_pool_takes_rendezvous_path():
    """A prompt that could never fit the cell pool even when empty must
    not starve in overflow — it follows the rendezvous discipline."""
    s = CellQueueScheduler(num_cells=2, cell_size=1024)
    # 800 tokens = 3200B: eager class, but needs 4 cells > pool of 2
    assert s.submit(_req(0, 800), 0.0) == "rendezvous"
    out = s.admit(1.0, free_slots=1)
    assert [q.rid for q in out] == [0] and out[0].cells == 0


def test_rendezvous_class_defers_until_slot_free():
    s = CellQueueScheduler(num_cells=8)
    # 2000-token prompt = 8000B > eager threshold -> rendezvous (1-copy)
    assert s.submit(_req(0, 2000), 0.0) == "rendezvous"
    assert s.submit(_req(1, 16), 0.0) == "cells"
    # no slot free: nothing moves (the handshake waits for the receiver)
    assert s.admit(1.0, free_slots=0) == []
    # buffered (cell) requests drain ahead of rendezvous ones
    out = s.admit(2.0, free_slots=2)
    assert [q.rid for q in out] == [1, 0]
    assert out[1].protocol == "one_copy" and out[1].cells == 0


def test_non_default_cell_classification_and_pricing_agree():
    """Bugfix: classification used the configured cell_size while pricing
    used the default HostModel cell — a multi-cell eager prompt was
    priced on the request-object-free fast path. Both now run through
    HostModel(cell=cell_size)."""
    s = CellQueueScheduler(num_cells=8, cell_size=256)
    # 128 tokens = 512B: > one 256B cell (not eager_fast), <= 4096B eager
    s.submit(_req(0, 128), 0.0)
    (q,) = s.admit(1.0, free_slots=1)
    assert q.protocol == "eager" and q.cells == 2
    m = protocol.HostModel(cell=256)
    assert q.admit_cost_s == pytest.approx(
        protocol.interthread_latency(512, m))
    # multi-cell eager pays the request object the fast path skips
    assert q.admit_cost_s > protocol.interthread_latency(512, m,
                                                         proto="eager_fast")
    assert s.modeled_admit_cost_s == pytest.approx(q.admit_cost_s)


def test_pool_oversized_eager_reclassified_as_one_copy():
    """Bugfix: an eager-class prompt re-routed to the rendezvous queue
    (it could never fit the cell pool) kept its eager protocol and eager
    price in the accounting rows; it is now reclassified + re-priced."""
    s = CellQueueScheduler(num_cells=2, cell_size=1024)
    # 800 tokens = 3200B: eager class, but needs 4 cells > pool of 2
    assert s.submit(_req(0, 800), 0.0) == "rendezvous"
    (q,) = s.admit(1.0, free_slots=1)
    assert q.protocol == "one_copy" and q.cells == 0
    m = protocol.HostModel(cell=1024)
    assert q.admit_cost_s == pytest.approx(
        protocol.interthread_latency(3200, m, proto="one_copy"))
    assert s.modeled_admit_cost_s == pytest.approx(q.admit_cost_s)


def test_chunked_handoff_pricing_matches_deposit_mechanics():
    """With prefill chunking on, every prompt larger than one chunk
    streams into its slot incrementally — rendezvous-class *and*
    multi-chunk eager-class prompts are priced as chunked handoffs
    (per-chunk envelopes on top of one handshake); prompts that fit a
    single chunk deposit whole and keep their eager price."""
    chunk_bytes = 64 * 4
    s = CellQueueScheduler(num_cells=8, prefill_chunk_bytes=chunk_bytes)
    s.submit(_req(0, 2000), 0.0)          # 8000B > eager threshold
    s.submit(_req(1, 200), 0.0)           # 800B eager class, 4 chunks
    s.submit(_req(2, 16), 0.0)            # 64B: fits one chunk
    admitted = {q.rid: q for q in s.admit(1.0, free_slots=3)}
    m = s.host_model
    assert admitted[0].admit_cost_s == pytest.approx(
        protocol.chunked_handoff_latency(8000, chunk_bytes, m))
    assert admitted[0].admit_cost_s > protocol.interthread_latency(8000, m)
    assert admitted[1].admit_cost_s == pytest.approx(
        protocol.chunked_handoff_latency(800, chunk_bytes, m))
    assert admitted[2].admit_cost_s == pytest.approx(
        protocol.interthread_latency(64, m))


def test_chunked_handoff_latency_model():
    m = protocol.HostModel()
    one = protocol.chunked_handoff_latency(8000, 8000, m)
    many = protocol.chunked_handoff_latency(8000, 256, m)
    assert many > one                       # more chunks, more envelopes
    assert many - one == pytest.approx((-(-8000 // 256) - 1) * m.t_envelope)
    with pytest.raises(ValueError):
        protocol.chunked_handoff_latency(100, 0)
    with pytest.raises(ValueError, match="unknown protocol"):
        protocol.interthread_latency(64, m, proto="two_copy")


def test_scheduler_reset_clears_queues_and_accounting():
    s = CellQueueScheduler(num_cells=2, cell_size=1024)
    s.submit(_req(0, 16), 0.0)
    s.submit(_req(1, 2000), 0.0)
    (q,) = s.admit(1.0, free_slots=1)
    q.generated = 1
    s.record_finish(q, 2.0)
    s.reset()
    assert s.num_waiting == 0 and s.cells_free == s.num_cells
    assert s.n_submitted == 0 and s.n_deferred == 0
    assert s.modeled_admit_cost_s == 0.0 and not s.finished
    assert s.submit(_req(2, 16), 3.0) == "cells"    # still usable


def test_scheduler_reset_clears_per_request_map():
    """Satellite bugfix: the rid-keyed arrival/accounting map must die
    at reset — every trial restarts rids at 0 (run_traffic's warm-up
    does exactly this), so a surviving warm-up entry would alias the
    real request with the same rid and leak its arrival into the next
    trial's accounting."""
    s = CellQueueScheduler(num_cells=4)
    warm = _req(0, 16, arrival=123.0)
    s.submit(warm, 123.0)
    assert s.req_log[0] is warm and s.req_log[0].arrival == 123.0
    s.reset()
    assert s.req_log == {}
    real = _req(0, 16, arrival=0.5)          # same rid, next trial
    s.submit(real, 0.5)
    assert s.req_log[0] is real and s.req_log[0].arrival == 0.5


def test_fifo_within_class_and_accounting():
    s = CellQueueScheduler(num_cells=16)
    for i in range(4):
        s.submit(_req(i, 16, arrival=float(i)), now=float(i))
    out = s.admit(5.0, free_slots=4)
    assert [q.rid for q in out] == [0, 1, 2, 3]
    for q in out:
        q.generated = 4
        s.record_finish(q, now=6.0)
    stats = s.latency_stats()
    assert stats["n"] == 4.0 and stats["tokens"] == 16.0
    assert stats["latency_p50_s"] == pytest.approx(6.0 - 1.5)
    assert s.modeled_admit_cost_s > 0.0   # protocol cost model engaged


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

class _StubModel:
    """Just enough of the Model bundle for SlotKVCache."""

    @staticmethod
    def init_cache(batch, cache_len, dtype=None):
        import jax.numpy as jnp
        return {"k": jnp.zeros((2, batch, cache_len, 1, 4), jnp.float32),
                "pos": jnp.full((2, cache_len), -1, jnp.int32)}


def test_slot_pool_alloc_free_lifecycle():
    import jax.numpy as jnp
    kv = SlotKVCache(_StubModel(), cache_len=8, num_slots=2)
    a = kv.alloc("req-a")
    b = kv.alloc("req-b")
    assert {a, b} == {0, 1} and kv.num_free == 0
    with pytest.raises(SlotError):
        kv.alloc("req-c")               # exhaustion is an error, not a wait
    one = _StubModel.init_cache(1, 8)
    kv.insert(a, one, length=5)
    kv.advance(a)
    assert kv.length(a) == 6 and kv.owner(a) == "req-a"
    kv.free(a)
    with pytest.raises(SlotError):
        kv.free(a)                      # double free
    with pytest.raises(SlotError):
        kv.insert(a, one, length=1)     # insert into freed slot
    assert kv.num_free == 1 and kv.live_slots == [b]
    # buffers keep the stacked leading slot dim
    assert kv.buffers["k"].shape == (2, 2, 1, 8, 1, 4)


def test_slot_rows_insert_at_and_reset_slot():
    """Chunked-handoff page API: gather slot rows, mutate, scatter back
    (out-of-range padding rows drop), blank a slot before streaming."""
    import jax.numpy as jnp
    kv = SlotKVCache(_StubModel(), cache_len=8, num_slots=3)
    a = kv.alloc("req-a")
    one = _StubModel.init_cache(1, 8)
    kv.insert(a, one, length=5)
    rows = kv.take_rows([a, kv.num_slots])          # second row = padding
    assert rows["k"].shape == (2, 2, 1, 8, 1, 4)
    rows = {"k": rows["k"] + 1.0, "pos": rows["pos"] * 0 + 3}
    kv.insert_at([a, kv.num_slots], rows, lengths=[7, 99])
    assert (np.asarray(kv.buffers["k"][a]) == 1.0).all()
    assert kv.length(a) == 7
    # padding row dropped: no other slot was touched (pool init is zeros)
    assert (np.asarray(kv.buffers["pos"][(a + 1) % 3]) == 0).all()
    kv.advance(a, 2)                                # append-pages account
    assert kv.length(a) == 9
    kv.reset_slot(a)
    assert (np.asarray(kv.buffers["pos"][a]) == -1).all()
    assert (np.asarray(kv.buffers["k"][a]) == 0.0).all()
    assert kv.length(a) == 0
    with pytest.raises(SlotError):
        kv.reset_slot((a + 1) % 3)                  # free slot
    # slot a is still leased: the reset must name the leak
    with pytest.warns(LeaseLeakWarning, match="req-a"):
        kv.reset()
    assert kv.num_free == 3 and kv.live_slots == []


# ---------------------------------------------------------------------------
# traces + replica fan-out
# ---------------------------------------------------------------------------

def test_make_trace_kinds_and_shard():
    tr = make_trace(8, prompt_len=16, max_new=(2, 6), arrival="poisson",
                    rate=100.0, seed=0)
    assert len(tr) == 8 and tr[0].arrival == 0.0
    assert all(t2.arrival >= t1.arrival for t1, t2 in zip(tr, tr[1:]))
    assert all(2 <= t.max_new <= 6 for t in tr)
    tb = make_trace(8, prompt_len=16, max_new=4, arrival="burst", burst=4,
                    rate=10.0)
    assert tb[0].arrival == tb[3].arrival and tb[4].arrival > tb[3].arrival
    with pytest.raises(ValueError):
        make_trace(4, prompt_len=8, max_new=2, arrival="bogus")
    # mixed prompt lengths cycle across the trace (short/long interleave)
    tm = make_trace(6, prompt_len=(16, 256), max_new=4, arrival="all")
    assert [e.prompt_len for e in tm] == [16, 256, 16, 256, 16, 256]
    s0, s1 = shard_trace(tr, 0, 2), shard_trace(tr, 1, 2)
    assert len(s0) + len(s1) == len(tr)
    assert not {id(e) for e in s0} & {id(e) for e in s1}
    with pytest.raises(ValueError):
        shard_trace(tr, 2, 2)


def test_shard_trace_seeded_exact_partition():
    """Satellite: seeded fan-out is deterministic and partitions the
    trace exactly — no dropped or duplicated request across replicas,
    for any replica count, with arrival order preserved per shard."""
    tr = make_trace(13, prompt_len=(16, 256), max_new=4,
                    arrival="poisson", rate=50.0, seed=3)
    for n_rep in (1, 2, 3, 5):
        shards = [shard_trace(tr, i, n_rep, seed=42) for i in range(n_rep)]
        ids = [id(e) for s in shards for e in s]
        assert len(ids) == len(tr)                 # nothing dropped
        assert set(ids) == {id(e) for e in tr}     # nothing duplicated
        for s in shards:
            assert all(b.arrival >= a.arrival for a, b in zip(s, s[1:]))
        # deterministic: same seed -> same deal, every replica agrees
        again = [shard_trace(tr, i, n_rep, seed=42) for i in range(n_rep)]
        assert all([id(e) for e in a] == [id(e) for e in b]
                   for a, b in zip(shards, again))
    # the seeded deal decorrelates from the 2-cycle prompt-length
    # interleave that round-robin hands entirely to one replica
    rr = shard_trace(tr, 0, 2)
    assert {e.prompt_len for e in rr} == {16}
    sd0, sd1 = (shard_trace(tr, i, 2, seed=0) for i in range(2))
    assert {e.prompt_len for e in sd0} == {16, 256}
    assert {e.prompt_len for e in sd1} == {16, 256}


# ---------------------------------------------------------------------------
# protocol-name validation (satellite: no silent 1-copy fallthrough)
# ---------------------------------------------------------------------------

def test_unknown_protocol_rejected():
    with pytest.raises(ValueError, match="unknown protocol"):
        protocol.validate_protocol("two_copy")
    with pytest.raises(ValueError, match="unknown protocol"):
        protocol.request_overhead(64, proto="two_copy")
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="unknown protocol"):
        p2p.send_recv(jnp.zeros((4,)), "ranks", [(0, 0)],
                      force_protocol="two_copy")
    # known names still accepted by the model helpers
    assert protocol.request_overhead(64, proto="eager_fast") == 0.0
    assert protocol.request_overhead(64, proto="one_copy") > 0.0
