"""Serving-path tests: ring-buffer KV cache (long-context decode) and
engine consistency between ring and full caches."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import Engine

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)


def _hymba_all_swa():
    # all-windowed variant (the long_500k serving mode)
    cfg = get_smoke_config("hymba-1.5b")
    return dataclasses.replace(cfg, global_layers=())


def test_ring_buffer_decode_matches_full_cache():
    """With all positions inside the window, ring-buffer decode must equal
    full-cache decode exactly."""
    cfg = _hymba_all_swa()   # window 16
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8              # everything fits in the window
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")

    logits_full, cache_full = jax.jit(
        lambda p, b: model.prefill(p, b, 32))(params, batch)
    logits_ring, cache_ring = jax.jit(
        lambda p, b: model.prefill(p, b, cfg.swa_window))(params, batch)
    np.testing.assert_allclose(np.asarray(logits_full),
                               np.asarray(logits_ring), atol=1e-4)
    tok = jnp.argmax(logits_full, -1).astype(jnp.int32)[:, None]
    lf, _ = jax.jit(model.decode_step)(params, cache_full, tok, jnp.int32(S))
    lr, _ = jax.jit(model.decode_step)(params, cache_ring, tok, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr), atol=1e-4)


def test_ring_buffer_long_decode_stays_finite():
    """Decode far past the window: ring slots recycle, logits stay finite
    and the cache positions always hold the last `window` positions."""
    cfg = _hymba_all_swa()
    model = build_model(cfg, TRAIN, ServeConfig(ring_buffer=True), tp=1)
    params = model.init(jax.random.PRNGKey(0))
    W = cfg.swa_window
    B, S = 1, 8
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, W))(params, batch)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for t in range(3 * W):   # run well past several window recyclings
        pos = S + t
        logits, cache = step(params, cache, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size]))), t
    pos_rows = np.asarray(cache["pos"])
    final = S + 3 * W - 1
    assert pos_rows.max() == final
    assert pos_rows.min() >= final - W + 1     # only the last W positions


def test_engine_with_ring_cache():
    cfg = _hymba_all_swa()
    model = build_model(cfg, TRAIN, ServeConfig(ring_buffer=True), tp=1)
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(model, params, cache_len=cfg.swa_window)
    batch = make_synthetic_batch(cfg, 2, 8, compute_dtype="float32")
    out = eng.generate({"tokens": batch["tokens"]}, max_new_tokens=24)
    assert out.shape == (2, 24)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
