"""Unified ``Comm`` API (core/comm.py): split/dup derivation and rank
translation, sub-comm collectives on multi-axis meshes, request-based
nonblocking ops with stream ordering, and epoch invalidation of derived
objects across ``finish()`` — the DESIGN.md §2 contract.

Multi-device semantics run as subprocess cases (see tests/helpers.py);
host-side lifecycle rules that need no devices run in-process.
"""

import pytest

from tests.helpers import run_case


def test_comm_split_dup_translation():
    run_case("comm_split_dup", ndev=8)


def test_subcomm_collectives_two_axis_mesh():
    run_case("comm_subcomm_collectives", ndev=8)


def test_requests_wait_test_ordering():
    run_case("comm_requests", ndev=8)


def test_epoch_invalidation_across_finish():
    run_case("comm_epoch_invalidation", ndev=8)


def test_serve_replica_fanout_split():
    run_case("serve_replica_fanout", ndev=8)


def test_waitall_mixed_send_recv_on_split_subcomm():
    """The fabric's KV-handoff pattern (DESIGN.md §10): waitall over a
    mixture of isend/irecv and collective requests issued on one stream
    of a split sub-comm, with epoch invalidation at finish."""
    run_case("comm_waitall_mixed", ndev=8)


# ---------------------------------------------------------------------------
# host-side lifecycle rules (single device, no shard_map)
# ---------------------------------------------------------------------------

def _single_device_comm():
    jax = pytest.importorskip("jax")
    from repro.core.comm import threadcomm_init
    from repro.core.compat import make_mesh
    mesh = make_mesh((1,), ("ranks",))
    return threadcomm_init(mesh, process_axes=(), thread_axes=("ranks",))


def test_inactive_comm_refuses_everything():
    from repro.core.comm import ThreadCommError
    tc = _single_device_comm()
    for call in (lambda: tc.thread_comm(), lambda: tc.dup(),
                 lambda: tc.split([0]), lambda: tc.stream("s"),
                 lambda: tc.group([0])):
        with pytest.raises(ThreadCommError):
            call()


def test_service_mode_start_finish_free():
    from repro.core.comm import ThreadCommError
    tc = _single_device_comm()
    tc.start()                      # bare start: long-lived activation
    sub = tc.thread_comm()
    assert sub.size == 1
    with pytest.raises(ThreadCommError):
        tc.start()                  # nested start forbidden
    with pytest.raises(ThreadCommError):
        tc.free()                   # free-while-active forbidden
    tc.finish()
    with pytest.raises(ThreadCommError):
        sub.dup()                   # derived object died at finish
    with pytest.raises(ThreadCommError):
        tc.finish()                 # unmatched finish
    tc.free()
    with pytest.raises(ThreadCommError):
        tc.start()                  # freed comm is gone


def test_split_validation():
    from repro.core.comm import ThreadCommError
    tc = _single_device_comm()
    with tc.start():
        with pytest.raises(ThreadCommError):
            tc.split([0, 1])        # wrong color length
        with pytest.raises(ThreadCommError):
            tc.split([0], key=[0, 1])   # wrong key length
        gone = tc.split([-1])       # MPI_UNDEFINED everywhere
        assert gone.families() == []
