"""Paged-KV serving: deterministic block-pool/PagedKVCache behavior and
the paged ContinuousEngine — greedy token parity against both the static
baseline and the slot-pool engine (the acceptance bar for the paged
refactor), block-gated admission deferral, early-EOS lease release, and
misuse errors naming the owner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import (BlockPool, ContinuousEngine, PagedKVCache,
                        SlotError, SlotKVCache, StaticEngine)

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)


def _bundle(arch="gemma-2b", seed=0):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    return cfg, model, model.init(jax.random.PRNGKey(seed))


def _prompt(cfg, B=4, S=8):
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    return {"tokens": batch["tokens"]}


# ---------------------------------------------------------------------------
# BlockPool / PagedKVCache (deterministic; property tests need hypothesis
# and live in tests/test_block_pool.py)
# ---------------------------------------------------------------------------

def test_pool_alloc_free_roundtrip():
    pool = BlockPool(num_blocks=8, block_size=16)
    blocks = pool.alloc(5, "req0")
    assert len(set(blocks)) == 5
    assert pool.num_free == 3 and pool.num_live == 5
    assert all(pool.refcount(b) == 1 for b in blocks)
    assert all(pool.owner(b) == "req0" for b in blocks)
    pool.free(blocks)
    assert pool.num_free == 8 and pool.num_live == 0


def test_pool_refcount_shared_block():
    pool = BlockPool(num_blocks=4, block_size=16)
    blocks = pool.alloc(2, "a")
    shared = blocks[0]
    pool.ref(shared)                       # second lease (shared prefix)
    pool.free(blocks)                      # first owner done
    assert pool.refcount(shared) == 1      # still live for the sharer
    assert pool.num_free == 3
    pool.free([shared])
    assert pool.num_free == 4


def test_pool_misuse_errors_name_owner():
    pool = BlockPool(num_blocks=2, block_size=16)
    blocks = pool.alloc(1, "req-42")
    pool.free(blocks)
    with pytest.raises(SlotError, match="req-42"):
        pool.free(blocks)
    with pytest.raises(SlotError, match="exhausted"):
        pool.alloc(3, "big")
    with pytest.raises(SlotError, match="free block"):
        pool.ref(blocks[0])


class _StubModel:
    @staticmethod
    def init_paged_cache(num_blocks, block_size, dtype=None,
                        num_rows=0):
        return {"k": np.zeros((1, num_blocks, block_size, 1, 1)),
                "v": np.zeros((1, num_blocks, block_size, 1, 1))}


def test_paged_cache_lease_overrun_and_double_free():
    kv = PagedKVCache(_StubModel(), num_blocks=16, block_size=4,
                      num_slots=4, max_blocks_per_req=8)
    row = kv.alloc("req7", 5)          # 2 blocks of 4 = 8 token lease
    kv.advance(row, 8)
    with pytest.raises(SlotError, match="overran its lease"):
        kv.advance(row, 1)
    kv.free(row)
    with pytest.raises(SlotError, match="req7"):
        kv.free(row)


def test_paged_cache_admission_gates():
    kv = PagedKVCache(_StubModel(), num_blocks=4, block_size=4,
                      num_slots=2, max_blocks_per_req=4)
    assert kv.can_admit(16)            # 4 blocks, exactly the pool
    r = kv.alloc("a", 4)
    assert not kv.can_admit(16)        # only 3 blocks left
    assert kv.can_admit(12)
    with pytest.raises(SlotError, match="max_blocks_per_req"):
        kv.can_admit(17)               # would exceed the per-request cap
    kv.free(r)
    assert kv.can_admit(16)


def test_host_length_bookkeeping_is_int32_both_pools():
    """Both pools keep host lengths in int32 — the device position dtype —
    and both name the last owner on double free."""
    kv = PagedKVCache(_StubModel(), num_blocks=16, block_size=4,
                      num_slots=4, max_blocks_per_req=8)
    row = kv.alloc("a", 3)
    kv.advance(row, 3)
    assert kv.lengths.dtype == np.int32 and kv.length(row) == 3

    class _SlotStub:
        @staticmethod
        def init_cache(batch, cache_len):
            return {"k": jnp.zeros((batch, cache_len, 1, 1))}

    slots = SlotKVCache(_SlotStub(), cache_len=8, num_slots=2)
    s = slots.alloc("owner-a")
    slots.advance(s, 5)
    assert slots.lengths.dtype == np.int32 and slots.length(s) == 5
    slots.free(s)
    with pytest.raises(SlotError, match="owner-a"):
        slots.free(s)


# ---------------------------------------------------------------------------
# paged engine parity (acceptance: token-identical to slot pool + static)
# ---------------------------------------------------------------------------

def _paged(model, params, **kw):
    kw.setdefault("cache_len", 24)
    kw.setdefault("num_slots", 4)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("block_size", 8)
    return ContinuousEngine(model, params, **kw)


def test_paged_greedy_parity_same_arrival_batch():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    static = StaticEngine(model, params, cache_len=24).generate(prompt, 12)
    slot = ContinuousEngine(model, params, cache_len=24, num_slots=4,
                            prefill_chunk=4).generate(prompt, 12)
    paged = _paged(model, params).generate(prompt, 12)
    assert np.array_equal(static, paged)
    assert np.array_equal(slot, paged)


def test_paged_parity_multi_chunk_prompts():
    """Prompts spanning several chunks AND several blocks (chunk != block
    size, neither dividing the prompt)."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=3, S=21)
    static = StaticEngine(model, params, cache_len=32).generate(prompt, 8)
    paged = _paged(model, params, cache_len=32, num_slots=3, prefill_chunk=6,
                   block_size=4).generate(prompt, 8)
    assert np.array_equal(static, paged)


def test_paged_parity_block_recycling():
    """More requests than the pool holds at once: blocks recycle across
    requests and stale pages of previous owners must not leak into
    attention (structural masking)."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    static = StaticEngine(model, params, cache_len=24).generate(prompt, 10)
    # pool: 6 blocks of 8 = 48 tokens; each request needs 3 blocks (8+10
    # tokens) -> at most 2 in flight, 4 requests recycle the pool
    paged = _paged(model, params, num_slots=2, num_blocks=6,
                   ).generate(prompt, 10)
    assert np.array_equal(static, paged)


def test_paged_admission_defers_on_blocks_not_rows():
    """Rows are plentiful; blocks are scarce: the engine must defer
    admission (head-of-line) and the deferral shows in the scheduler's
    block-deferral counter."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    eng = _paged(model, params, num_slots=4, num_blocks=3)  # 1 req at a time
    out = eng.generate(prompt, 6)
    assert out.shape == (4, 6)
    assert eng.scheduler.n_block_deferrals > 0
    assert eng.kv.num_live == 0 and eng.kv.num_free_blocks == 3


def test_paged_eos_frees_lease_early():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    ref = StaticEngine(model, params, cache_len=40).generate(prompt, 16)
    eos = int(ref[0, 3])               # force an early EOS for row 0
    eng = _paged(model, params, cache_len=40, num_slots=2, eos_id=eos)
    out = eng.generate(prompt, 16)
    assert eng.kv.num_live == 0
    assert eng.kv.num_free_blocks == eng.kv.pool.num_blocks
    hit = np.flatnonzero(out[0] == eos)
    assert hit.size and (out[0, int(hit[0]):] == eos).all()


def test_paged_engine_reset_restores_pool():
    cfg, model, params = _bundle()
    eng = _paged(model, params)
    eng.generate(_prompt(cfg, B=2, S=8), 4)
    eng.reset()
    assert eng.kv.num_live == 0
    assert eng.kv.num_free_blocks == eng.kv.pool.num_blocks
    assert eng.peak_live == 0 and eng.scheduler.num_waiting == 0
    out = eng.generate(_prompt(cfg, B=2, S=8), 4)   # reusable after reset
    assert out.shape == (2, 4)


def test_paged_oversized_request_rejected_at_submit():
    """A request whose prompt+max_new can never fit its block table must
    fail loudly at submit — not crash the serve loop from the admission
    gate once it reaches the queue head."""
    from repro.serve import ServeRequest
    cfg, model, params = _bundle()
    # capacity: ceil(24/8)=3 blocks x 8 = 24 tokens; 8 + 20 = 28 > 24
    eng = _paged(model, params)
    batch = make_synthetic_batch(cfg, 1, 8, compute_dtype="float32")
    req = ServeRequest(rid=0, batch={"tokens": np.asarray(batch["tokens"])},
                       max_new_tokens=20)
    with pytest.raises(ValueError, match="admittable capacity"):
        eng.submit(req)
    assert eng.scheduler.num_waiting == 0      # nothing poisoned the queue

    # lease fits the per-request table but NOT the whole pool: must also
    # be rejected at submit, not deferred forever (admission livelock)
    small = _paged(model, params, num_slots=1, num_blocks=2)   # 16 tokens
    req2 = ServeRequest(rid=1, batch={"tokens": np.asarray(batch["tokens"])},
                        max_new_tokens=12)                     # needs 20
    with pytest.raises(ValueError, match="admittable capacity"):
        small.submit(req2)


def test_paged_requires_chunked_deposit():
    cfg, model, params = _bundle()
    with pytest.raises(ValueError, match="chunk"):
        ContinuousEngine(model, params, cache_len=24, num_slots=2,
                         prefill_chunk=0, kv_layout="paged")


def test_paged_ssm_family_runs_with_parity():
    """SSM families run the paged path (carried state threaded through
    row-aligned pool leaves — DESIGN.md §13) token-identically to the
    static baseline; the old dense-only gate is gone."""
    cfg, mamba_model, mamba_params = _bundle("mamba2-370m")
    prompt = _prompt(cfg, B=2, S=8)
    static = StaticEngine(mamba_model, mamba_params,
                          cache_len=24).generate(prompt, 6)
    eng = ContinuousEngine(mamba_model, mamba_params, cache_len=24,
                           num_slots=2, prefill_chunk=8,
                           kv_layout="paged", block_size=4)
    assert np.array_equal(static, eng.generate(prompt, 6))


def test_paged_temperature_determinism():
    """Same seed + temperature: slot and paged engines draw identical
    tokens (per-request key chains are layout-independent)."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=3, S=8)
    a = ContinuousEngine(model, params, cache_len=24, num_slots=3,
                         prefill_chunk=4).generate(
        prompt, 10, temperature=0.7, seed=3)
    b = _paged(model, params, num_slots=3).generate(
        prompt, 10, temperature=0.7, seed=3)
    assert np.array_equal(a, b)
